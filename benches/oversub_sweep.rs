//! Oversubscription sweep — the scenario family the `net` fabric opens up
//! beyond the paper's flat testbed: AdaDUAL (Ada-SRSF) vs SRSF(n) on a
//! two-tier topology (racks of 4 behind a shared core) as the core's
//! downlink:uplink ratio degrades 1:1 → 2:1 → 4:1 → 8:1.
//!
//! Expected shape (docs/EXPERIMENTS.md §Oversub): every policy's JCT grows
//! with the ratio — cross-rack All-Reduces drain through a link whose
//! per-byte time is scaled by it — and the gap between contention-avoiding
//! admission (SRSF(1)/Ada-SRSF) and blind acceptance (SRSF(2)/(3)) widens,
//! because each collision on the slow core link costs proportionally more.
//!
//! Run: `cargo bench --bench oversub_sweep`

use ddl_sched::prelude::*;
use ddl_sched::util::bench::BenchReport;

const RATIOS: [f64; 4] = [1.0, 2.0, 4.0, 8.0];

fn main() {
    let base = Scenario {
        name: "oversub".to_string(),
        placer: "lwf-rack".to_string(),
        topology: TopologySpec::TwoTier {
            rack_size: net::DEFAULT_RACK_SIZE,
            oversubscription: 1.0,
        },
        ..Scenario::paper()
    };
    let exp = Experiment {
        policies: registry::POLICIES.iter().map(|s| s.to_string()).collect(),
        oversubs: RATIOS.to_vec(),
        ..Experiment::single(base)
    };
    let t0 = std::time::Instant::now();
    let records = exp.run(Experiment::default_threads()).unwrap();
    let wall = t0.elapsed().as_secs_f64();

    // Machine-readable trajectory dump (per-cell event counts; the grid
    // is timed as a whole, recorded as the summary row).
    let mut report = BenchReport::new("oversub_sweep");
    for r in &records {
        report.record_events(&format!("{} {}", r.scenario.name, r.scenario.label()), r.n_events);
    }
    report.record("sweep total", records.iter().map(|r| r.n_events).sum(), wall);
    print!("{}", report.delta_vs_committed());
    match report.write() {
        Ok(path) => println!("wrote {path}"),
        Err(e) => eprintln!("could not write bench report: {e}"),
    }

    let mut t = Table::new(
        "two-tier core oversubscription — avg JCT(s), LWF-rack-1 placement",
        &["policy", "1:1", "2:1", "4:1", "8:1"],
    );
    let cell = |policy: &str, ratio: f64| cell_of(&records, policy, ratio);
    for policy in registry::POLICIES {
        let mut row = vec![registry::policy_label(policy)];
        for ratio in RATIOS {
            row.push(format!("{:.0}", cell(policy, ratio).eval.jct.mean));
        }
        t.row(&row);
    }
    t.print();

    for policy in registry::POLICIES {
        let rows: Vec<Vec<f64>> = RATIOS
            .iter()
            .map(|&ratio| {
                let r = cell(policy, ratio);
                vec![
                    ratio,
                    r.eval.jct.mean,
                    r.eval.jct.p95,
                    r.eval.avg_gpu_util,
                    r.max_contention as f64,
                ]
            })
            .collect();
        let _ = write_csv(
            &format!("oversub_{policy}"),
            &["oversub", "avg_jct_s", "p95_jct_s", "avg_util", "max_k"],
            &rows,
        );
    }

    println!("\nshape checks:");
    for policy in registry::POLICIES {
        let flat = cell(policy, 1.0).eval.jct.mean;
        let worst = cell(policy, 8.0).eval.jct.mean;
        println!(
            "  {} degrades monotonically with the core ratio: {}",
            registry::policy_label(policy),
            ok(worst >= flat)
        );
    }
    let gap = |r: f64| cell("srsf3", r).eval.jct.mean - cell("ada", r).eval.jct.mean;
    println!(
        "  Ada-SRSF's edge over SRSF(3) grows with oversubscription: {}",
        ok(gap(8.0) >= gap(1.0))
    );
}

fn cell_of<'a>(records: &'a [RunRecord], policy: &str, ratio: f64) -> &'a RunRecord {
    records
        .iter()
        .find(|r| {
            r.scenario.policy == policy
                && matches!(
                    r.scenario.topology,
                    TopologySpec::TwoTier { oversubscription, .. }
                        if oversubscription == ratio
                )
        })
        .unwrap()
}

fn ok(b: bool) -> &'static str {
    if b { "OK" } else { "DIVERGES" }
}
